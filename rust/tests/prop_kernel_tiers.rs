//! Differential property harness for the width-tiered integer kernels
//! and the compiled zero-free MAC schedules (ARCHITECTURE.md §Kernel
//! tiering, §Compiled layer schedules): over randomly generated small
//! `ModelIr` graphs — including a 0–95% weight-sparsity axis — and
//! adversarial mantissa fills, the scheduled `BatchEmulator` must be
//! **bit-identical** to the forced-branchy tiered path, the forced-wide
//! i64 path and the sequential scalar `Emulator` — for every batch size
//! and thread count. Plus tier-boundary unit tests where the proven
//! accumulator bound sits exactly at each machine type's limit and one
//! element over, a dead-element exclusion regression, and the
//! frac-uniformity invariant the schedules fold shifts on.

use hgq::firmware::emulator::Emulator;
use hgq::firmware::{ActQ, Calib, FwLayer, Graph, QuantWeights};
use hgq::fixed::FixedSpec;
use hgq::ir::tier::KernelTier;
use hgq::serve::batch::{infer_all, BatchEmulator};
use hgq::serve::Registry;
use hgq::util::prop::{check, gen_model_ir};

/// The three dispatch modes under test, as `(force_branchy,
/// force_wide)` emulator flags: compiled schedules (the default),
/// branchy tiered kernels, and the i64 reference path.
const MODES: [(bool, bool); 3] = [(false, false), (true, false), (false, true)];

/// Adversarial input fill derived from the graph's own input specs:
/// 0 = all-amax, 1 = all-amin, 2 = sign-alternating extremes,
/// 3 = boundary-straddling (half a step OUTSIDE the range, so
/// round-half-up lands exactly on the wrap boundary).
fn adversarial_fill(g: &Graph, kind: usize, n: usize) -> Vec<f32> {
    let din = g.input_dim;
    let q = match &g.layers[0] {
        FwLayer::InputQuant { out } => out,
        other => panic!("first layer must be an input quantizer, got {other:?}"),
    };
    let mut x = vec![0.0f32; n * din];
    for s in 0..n {
        for i in 0..din {
            let sp = q.spec(i);
            let v = match kind {
                0 => sp.max_value(),
                1 => sp.min_value(),
                2 => {
                    if (s + i) % 2 == 0 {
                        sp.max_value()
                    } else {
                        sp.min_value()
                    }
                }
                _ => {
                    if (s + i) % 2 == 0 {
                        sp.max_value() + 0.5 * sp.step()
                    } else {
                        sp.min_value() - 0.5 * sp.step()
                    }
                }
            };
            x[s * din + i] = v as f32;
        }
    }
    x
}

/// Golden logits: one sample at a time through the scalar i64 emulator.
fn sequential(g: &Graph, x: &[f32], n: usize) -> Vec<f64> {
    let (din, k) = (g.input_dim, g.output_dim);
    let mut em = Emulator::new(g);
    let mut out = vec![0.0f64; n * k];
    for s in 0..n {
        em.infer(&x[s * din..(s + 1) * din], &mut out[s * k..(s + 1) * k]).unwrap();
    }
    out
}

/// The tentpole property: 4 adversarial fills x 250 generated graphs
/// (1000 cases, each drawing a 0–95% weight-sparsity level), checked at
/// batch sizes {1, 3, 32} in all three dispatch modes — compiled
/// schedules, forced-branchy tiered kernels, forced-wide i64 — against
/// the scalar reference. All four must agree bit-for-bit.
#[test]
fn prop_tiered_matches_wide_and_scalar_bitwise() {
    const N: usize = 32;
    let mut narrow_layers = 0usize;
    let mut scheduled_layers = 0usize;
    let mut dropped_zeros = 0usize;
    let mut sparse_graphs = 0usize;
    for kind in 0..4usize {
        check(&format!("tiered-vs-wide-fill{kind}"), 250, |rng| {
            let gm = gen_model_ir(rng);
            let calib = Calib { amin: gm.amin.clone(), amax: gm.amax.clone() };
            let g = Graph::from_ir(&gm.ir, &gm.state, &calib)
                .map_err(|e| format!("graph build failed: {e}"))?;
            let plan = g.plan();
            narrow_layers += plan
                .kernels
                .iter()
                .filter(|k| k.bound.is_some() && k.tier != KernelTier::Wide)
                .count();
            scheduled_layers += plan.scheduled_layers();
            // zeros the schedules actually dropped: every zero weight of
            // a layer that compiled a schedule never reaches the kernel
            for (l, sc) in g.layers.iter().zip(plan.schedules.iter()) {
                if sc.is_some() {
                    if let FwLayer::Dense { w, .. } | FwLayer::Conv2d { w, .. } = l {
                        dropped_zeros += w.m.iter().filter(|&&m| m == 0).count();
                    }
                }
            }
            if g.sparsity() >= 0.8 {
                sparse_graphs += 1;
            }
            let x = adversarial_fill(&g, kind, N);
            let golden = sequential(&g, &x, N);
            let (din, k) = (g.input_dim, g.output_dim);
            for bsz in [1usize, 3, 32] {
                for (branchy, wide) in MODES {
                    let mut bem = BatchEmulator::new(&g, bsz)
                        .with_force_wide(wide)
                        .with_force_branchy(branchy);
                    let mut got = vec![0.0f64; N * k];
                    let mut done = 0usize;
                    while done < N {
                        let take = bsz.min(N - done);
                        bem.infer_batch(
                            &x[done * din..(done + take) * din],
                            &mut got[done * k..(done + take) * k],
                        )
                        .map_err(|e| e.to_string())?;
                        done += take;
                    }
                    if got != golden {
                        return Err(format!(
                            "batch {bsz} force_branchy {branchy} force_wide {wide} diverged \
                             from the scalar reference (plan {:?})",
                            plan.kernels
                        ));
                    }
                }
            }
            Ok(())
        });
    }
    // non-vacuity: across 1000 generated graphs, narrow tiers, compiled
    // schedules, dropped zero weights and the high-sparsity regime must
    // all have actually engaged — otherwise the property proved nothing
    assert!(
        narrow_layers > 0,
        "no narrow-tier MAC layer was ever exercised; the differential property is vacuous"
    );
    assert!(
        scheduled_layers > 0,
        "no MAC layer ever compiled a schedule; the scheduled mode tested nothing"
    );
    assert!(
        dropped_zeros > 0,
        "no scheduled layer carried a zero weight; the zero-free claim went untested"
    );
    assert!(
        sparse_graphs > 0,
        "no generated graph reached 80% weight sparsity; the pruned regime went untested"
    );
}

/// The fixed 16-shard grid on top of tiered kernels stays bit-identical
/// for every worker-thread count.
#[test]
fn prop_tiering_is_thread_count_invariant() {
    const N: usize = 37; // odd: ragged shards + ragged micro-batches
    check("tiered-thread-invariance", 40, |rng| {
        let gm = gen_model_ir(rng);
        let calib = Calib { amin: gm.amin.clone(), amax: gm.amax.clone() };
        let g = Graph::from_ir(&gm.ir, &gm.state, &calib)
            .map_err(|e| format!("graph build failed: {e}"))?;
        let x = adversarial_fill(&g, rng.below(4), N);
        let k = g.output_dim;
        let want = sequential(&g, &x, N);
        for threads in [1usize, 3, 16] {
            let mut got = vec![0.0f64; N * k];
            infer_all(&g, &x, &mut got, threads, 4).map_err(|e| e.to_string())?;
            if got != want {
                return Err(format!("threads {threads} diverged from the scalar reference"));
            }
        }
        Ok(())
    });
}

/// Every place the plan proves a static output frac plane, the runtime
/// frac of every sample must match it exactly (and is therefore uniform
/// across the batch) — the invariant that makes per-entry shifts
/// compile-time constants. Returns the number of (layer, element) slots
/// checked so callers can assert non-vacuity.
fn assert_static_fracs(g: &Graph, x: &[f32], n: usize) -> Result<usize, String> {
    let plan = g.plan();
    let mut bem = BatchEmulator::new(g, n);
    let mut out = vec![0.0f64; n * g.output_dim];
    let mut checked = 0usize;
    let mut bad: Option<String> = None;
    bem.infer_batch_probed(x, &mut out, &mut |li, n_elems, f_plane, stride, live| {
        let Some(fr) = plan.out_fracs[li].as_ref() else {
            return; // mixed-LSB pool downstream: frac is sample-dependent
        };
        if fr.len() != n_elems {
            bad.get_or_insert(format!(
                "layer {li}: plan snapshot has {} fracs, runtime plane {n_elems}",
                fr.len()
            ));
            return;
        }
        for i in 0..n_elems {
            for sa in 0..live {
                let got = f_plane[i * stride + sa];
                if got != fr[i] && bad.is_none() {
                    bad = Some(format!(
                        "layer {li} elem {i} sample {sa}: runtime frac {got} != static {}",
                        fr[i]
                    ));
                }
            }
            checked += 1;
        }
    })
    .map_err(|e| e.to_string())?;
    match bad {
        Some(b) => Err(b),
        None => Ok(checked),
    }
}

/// Frac uniformity on every shipped preset: the five paper models all
/// run through the probed batch emulator and every statically-proven
/// frac plane must match the runtime plane sample-for-sample.
#[test]
fn preset_fracs_are_static_and_uniform() {
    let reg = Registry::new("artifacts").with_calib_samples(32);
    for model in ["jets_pp", "jets_lw", "muon_pp", "muon_lw", "svhn_stream"] {
        let g = reg.get(model).unwrap();
        let n = 8usize;
        let x: Vec<f32> =
            (0..n * g.input_dim).map(|i| ((i % 23) as f32 - 11.0) / 8.0).collect();
        let checked = assert_static_fracs(&g, &x, n).unwrap_or_else(|e| panic!("{model}: {e}"));
        assert!(checked > 0, "{model}: no static frac plane was ever checked");
    }
}

/// Frac uniformity over 200 generated graphs with adversarial fills —
/// the same model space the bit-exactness property runs on, including
/// the sparsity axis and mixed-LSB pools (which must be the *only*
/// layers the plan declines to prove).
#[test]
fn prop_generated_fracs_are_static_and_uniform() {
    const N: usize = 9;
    let mut checked_total = 0usize;
    check("frac-uniformity", 200, |rng| {
        let gm = gen_model_ir(rng);
        let calib = Calib { amin: gm.amin.clone(), amax: gm.amax.clone() };
        let g = Graph::from_ir(&gm.ir, &gm.state, &calib)
            .map_err(|e| format!("graph build failed: {e}"))?;
        let x = adversarial_fill(&g, rng.below(4), N);
        checked_total += assert_static_fracs(&g, &x, N)?;
        Ok(())
    });
    assert!(checked_total > 0, "no static frac plane was ever checked");
}

/// A 1x1 dense graph whose proven accumulator bound is exactly `|wm|`:
/// the unsigned 1-bit input contributes mantissa 1, the bias is zero,
/// and the wrap-free 63-bit output passes the accumulator through.
fn one_weight_graph(wm: i64) -> Graph {
    Graph {
        name: "tier-boundary".to_string(),
        task: "reg".to_string(),
        dataset: "synth".to_string(),
        input_dim: 1,
        output_dim: 1,
        plan_cache: Default::default(),
        layers: vec![
            FwLayer::InputQuant {
                out: ActQ { specs: vec![FixedSpec::new(false, 1, 1)], scalar: true },
            },
            FwLayer::Dense {
                din: 1,
                dout: 1,
                w: QuantWeights { m: vec![wm], frac: vec![0] },
                b: QuantWeights { m: vec![0], frac: vec![0] },
                relu: false,
                out: ActQ { specs: vec![FixedSpec::new(true, 63, 63)], scalar: true },
                acc_frac: 0,
            },
        ],
    }
}

/// At each type's MAX the bound proves that tier; one element over
/// widens — and the boundary value itself survives the scheduled
/// kernel, the branchy narrow kernel, the wide kernel and the scalar
/// emulator unchanged (no wrap).
#[test]
fn tier_boundaries_hold_exactly() {
    let cases: [(i64, u128, KernelTier); 6] = [
        (127, 127, KernelTier::I8),
        (-128, 128, KernelTier::I16),
        (32767, 32767, KernelTier::I16),
        (-32768, 32768, KernelTier::I32),
        (i32::MAX as i64, i32::MAX as u128, KernelTier::I32),
        (-(1i64 << 31), 1u128 << 31, KernelTier::Wide),
    ];
    for (wm, bound, tier) in cases {
        let g = one_weight_graph(wm);
        let plan = g.kernel_plan();
        assert_eq!(plan[1].bound, Some(bound), "bound for wm={wm}");
        assert_eq!(plan[1].tier, tier, "tier for wm={wm}");
        let x = [1.0f32];
        let mut seq = [0.0f64];
        Emulator::new(&g).infer(&x, &mut seq).unwrap();
        assert_eq!(seq[0], wm as f64, "scalar reference for wm={wm}");
        for (branchy, wide) in MODES {
            let mut bem =
                BatchEmulator::new(&g, 1).with_force_wide(wide).with_force_branchy(branchy);
            let mut got = [0.0f64];
            bem.infer_batch(&x, &mut got).unwrap();
            assert_eq!(got[0], wm as f64, "wm={wm} branchy={branchy} wide={wide}");
        }
    }
}

/// A dense graph with a statically dead input element (`bits == 0`, so
/// its mantissa is provably 0 — a pruned/dead quantizer group) that
/// still carries nonzero weights, with a large `int_bits` making the
/// dead row's accumulator shift 32 — wider than the i8 kernel the layer
/// tiers to. The compiled schedule must exclude the dead row entirely
/// (never folding its out-of-range shift), while the branchy and wide
/// paths multiply it by the guaranteed-zero mantissa under the
/// per-sample shift clamp. All paths must agree bit-for-bit.
fn dead_element_graph() -> Graph {
    Graph {
        name: "dead-element".to_string(),
        task: "reg".to_string(),
        dataset: "synth".to_string(),
        input_dim: 2,
        output_dim: 2,
        plan_cache: Default::default(),
        layers: vec![
            FwLayer::InputQuant {
                out: ActQ {
                    specs: vec![FixedSpec::new(true, 4, 2), FixedSpec::new(true, 0, 30)],
                    scalar: false,
                },
            },
            FwLayer::Dense {
                din: 2,
                dout: 2,
                w: QuantWeights { m: vec![1, 2, 3, 4], frac: vec![2; 4] },
                b: QuantWeights { m: vec![1, -1], frac: vec![2, 2] },
                relu: false,
                out: ActQ { specs: vec![FixedSpec::new(true, 20, 10)], scalar: true },
                acc_frac: 4,
            },
        ],
    }
}

#[test]
fn dead_elements_are_excluded_and_bit_exact() {
    let g = dead_element_graph();
    let plan = g.plan();
    assert_eq!(plan.kernels[1].tier, KernelTier::I8, "dead rows must not widen the tier");
    let sc = plan.schedules[1]
        .as_ref()
        .expect("a dead row must not abort the layer's schedule");
    assert!(sc.folded, "narrow tier schedules fold shifts into weights");
    assert_eq!(sc.n_entries(), 2, "only the live element's two weights are scheduled");
    assert!(
        sc.entries.iter().all(|e| e.elem == 0),
        "the dead element's entries must be excluded: {:?}",
        sc.entries
    );
    // live extremes alongside junk on the dead element (quantizes to 0)
    let x = [1.75f32, 99.0, -2.0, -7.5, 0.25, 0.0];
    let n = 3;
    let want = sequential(&g, &x, n);
    for (branchy, wide) in MODES {
        let mut bem = BatchEmulator::new(&g, n).with_force_wide(wide).with_force_branchy(branchy);
        let mut got = vec![0.0f64; n * g.output_dim];
        bem.infer_batch(&x, &mut got).unwrap();
        assert_eq!(got, want, "branchy={branchy} wide={wide}");
    }
}
