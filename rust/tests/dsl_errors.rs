//! Golden parse-error suite for the `.hgq` DSL: every malformed file
//! under `tests/fixtures/dsl/` must produce a spanned [`Diagnostic`] —
//! never a panic — whose locus (`file:line:col`), message and help note
//! match the expectations pinned here, and whose full caret-underlined
//! rendering matches the committed `<fixture>.expected` golden file.
//!
//! The `.expected` fixtures are self-bootstrapping (same idiom as
//! `hls_golden.rs`): a missing file is written on first run (commit
//! it); set `HGQ_UPDATE_FIXTURES=1` to regenerate after an intentional
//! diagnostics change. The structural assertions below hold either way,
//! so a bootstrap run still fails on a wrong line/col or message.

use std::collections::BTreeSet;
use std::panic::AssertUnwindSafe;
use std::path::{Path, PathBuf};

struct Case {
    /// fixture stem under tests/fixtures/dsl/ (without `.hgq`)
    name: &'static str,
    /// expected 1-based diagnostic line
    line: usize,
    /// expected 1-based diagnostic column
    col: usize,
    /// required fragment of the diagnostic message
    msg_has: &'static str,
    /// required fragment of the `help:` note, if one must be present
    help_has: Option<&'static str>,
}

const CASES: &[Case] = &[
    Case {
        name: "near_miss_keyword",
        line: 2,
        col: 3,
        msg_has: "unknown field `tsak`",
        help_has: Some("did you mean `task`?"),
    },
    Case {
        name: "missing_required_field",
        line: 1,
        col: 7,
        msg_has: "missing the required `batch` field",
        help_has: None,
    },
    Case {
        name: "duplicate_layer",
        line: 7,
        col: 9,
        msg_has: "duplicate layer name `d0`",
        help_has: None,
    },
    Case {
        name: "reserved_inq",
        line: 6,
        col: 9,
        msg_has: "layer name `inq` is reserved",
        help_has: Some("pick another name"),
    },
    Case {
        name: "layer_before_input",
        line: 5,
        col: 3,
        msg_has: "layer `d0` declared before the `input` field",
        help_has: Some("declare `input [shape]` before the first layer"),
    },
    Case {
        name: "conv_on_flat_input",
        line: 6,
        col: 3,
        msg_has: "conv2d `c0`",
        help_has: None,
    },
    Case {
        name: "non_integer_batch",
        line: 4,
        col: 9,
        msg_has: "`batch` needs a non-negative integer, got `2.5`",
        help_has: None,
    },
    Case {
        name: "bad_number",
        line: 2,
        col: 9,
        msg_has: "malformed number `1.2.3`",
        help_has: None,
    },
    Case {
        name: "unterminated_string",
        line: 1,
        col: 7,
        msg_has: "unterminated string",
        help_has: None,
    },
    Case {
        name: "duplicate_model_block",
        line: 9,
        col: 1,
        msg_has: "duplicate `model` block (one per file)",
        help_has: None,
    },
    Case {
        name: "unknown_top_block",
        line: 1,
        col: 1,
        msg_has: "unknown block `modle`",
        help_has: Some("did you mean `model`?"),
    },
    Case {
        name: "beta_ramp_missing_to",
        line: 10,
        col: 22,
        msg_has: "expected `to` between the ramp endpoints",
        help_has: None,
    },
    Case {
        name: "empty",
        line: 2,
        col: 1,
        msg_has: "file contains no `model` block",
        help_has: None,
    },
];

fn fixture_dir() -> PathBuf {
    Path::new("tests/fixtures/dsl").to_path_buf()
}

#[test]
fn malformed_fixtures_yield_spanned_diagnostics() {
    for c in CASES {
        let path = fixture_dir().join(format!("{}.hgq", c.name));
        let file = path.to_string_lossy().to_string();
        let src = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("{}: reading fixture: {e}", c.name));

        // the hard promise: malformed input is a Diagnostic, not a panic
        let parsed = std::panic::catch_unwind(AssertUnwindSafe(|| {
            hgq::dsl::parse_str(&src, &file)
        }))
        .unwrap_or_else(|_| panic!("{}: parser panicked on malformed input", c.name));
        let d = parsed.expect_err(&format!("{}: fixture unexpectedly parsed", c.name));

        assert_eq!((d.line, d.col), (c.line, c.col), "{}: wrong locus\n{}", c.name, d.render());
        assert!(d.msg.contains(c.msg_has), "{}: message drifted:\n{}", c.name, d.render());
        if let Some(h) = c.help_has {
            let help = d.help.as_deref().unwrap_or_else(|| panic!("{}: help note missing", c.name));
            assert!(help.contains(h), "{}: help drifted: {help}", c.name);
        }

        let rendered = d.render();
        assert!(
            rendered.contains(&format!(" --> {file}:{}:{}", c.line, c.col)),
            "{}: rendering lacks the file:line:col locus:\n{rendered}",
            c.name
        );
        assert!(
            rendered.lines().any(|l| l.trim_start().starts_with('|') && l.contains('^')),
            "{}: rendering lacks a caret underline:\n{rendered}",
            c.name
        );

        // golden compare against the committed rendering
        let fx = fixture_dir().join(format!("{}.expected", c.name));
        let update = std::env::var("HGQ_UPDATE_FIXTURES").is_ok_and(|v| !v.is_empty());
        if update || !fx.exists() {
            std::fs::write(&fx, &rendered).expect("write expected fixture");
        }
        let want = std::fs::read_to_string(&fx).expect("read expected fixture");
        assert!(
            rendered == want,
            "{}: diagnostic drifted from {} — if the change is intentional, regenerate \
             with HGQ_UPDATE_FIXTURES=1 and commit the new fixture.\ngot:\n{rendered}\nwant:\n{want}",
            c.name,
            fx.display()
        );
    }
}

#[test]
fn every_fixture_file_is_covered() {
    let on_disk: BTreeSet<String> = std::fs::read_dir(fixture_dir())
        .expect("fixture dir")
        .filter_map(|e| {
            let p = e.expect("dir entry").path();
            (p.extension().is_some_and(|x| x == "hgq"))
                .then(|| p.file_stem().unwrap().to_string_lossy().to_string())
        })
        .collect();
    let pinned: BTreeSet<String> = CASES.iter().map(|c| c.name.to_string()).collect();
    assert_eq!(
        on_disk, pinned,
        "tests/fixtures/dsl/*.hgq and the pinned CASES table must stay in sync"
    );
}

#[test]
fn diagnostics_render_without_error_prefix() {
    // the CLI prepends `error:` itself; a prefix baked into render()
    // would double it
    let d = hgq::dsl::parse_str("model 42", "m.hgq").unwrap_err();
    assert!(!d.render().starts_with("error"), "{}", d.render());
    // Display goes through the same rendering (anyhow context chains)
    assert_eq!(format!("{d}"), d.render());
}
