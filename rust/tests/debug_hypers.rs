//! Probe: scalar hyper routing through the native train step
//! (regression guard — originally caught a print_large_constants
//! lowering bug on the AOT path; now also pins the native backend's
//! effective-lr and loss-term semantics).

use std::path::PathBuf;

use hgq::runtime::{self, Hypers, ModelRuntime, Runtime, Target};

#[test]
fn scalar_hypers_reach_the_computation() {
    // no artifacts present: the native backend synthesizes the preset
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = Runtime::new().unwrap();
    let mr = ModelRuntime::load(&rt, &p, "jets_lw").unwrap();
    let mut s0 = mr.init_state();
    for t in &mr.meta.tensors {
        if t.seg == "fbit" {
            s0[t.offset..t.offset + t.size].fill(6.0);
        }
    }
    let x: Vec<f32> = (0..mr.meta.batch * 16).map(|i| ((i % 31) as f32 - 15.0) / 8.0).collect();
    let y: Vec<i32> = (0..mr.meta.batch).map(|i| (i % 5) as i32).collect();
    let run = |h: Hypers| -> (f32, Vec<f32>) {
        let out = runtime::train_step(&mr, &s0, &x, Target::Cls(&y), h).unwrap();
        (out.loss, out.state[mr.meta.n_params..mr.meta.n_train].to_vec())
    };
    let base = run(Hypers { beta: 0.0, gamma: 0.0, lr: 0.0, f_lr: 0.0 });
    // f_lr = 0 freezes bitwidths even at lr = 1
    let frozen = run(Hypers { beta: 0.0, gamma: 0.0, lr: 1.0, f_lr: 0.0 });
    let moved = frozen.1.iter().zip(&base.1).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
    assert_eq!(moved, 0.0, "f_lr=0 must freeze bitwidths");
    // f_lr > 0 moves them
    let live = run(Hypers { beta: 0.0, gamma: 0.0, lr: 1.0, f_lr: 1.0 });
    let moved = live.1.iter().zip(&base.1).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
    assert!(moved > 0.0, "f_lr=1 must move bitwidths");
    // beta scales the loss by ~EBOPs-bar, gamma by ~L1
    let lb = run(Hypers { beta: 1.0, gamma: 0.0, lr: 0.0, f_lr: 0.0 }).0;
    let lg = run(Hypers { beta: 0.0, gamma: 1.0, lr: 0.0, f_lr: 0.0 }).0;
    assert!(lb > base.0 + 1.0, "beta must reach the loss");
    assert!(lg > base.0 + 1.0, "gamma must reach the loss");
}
