//! Layer-IR consistency: `ir::ModelIr` is the single structural source
//! of truth — these tests pin its inferred shapes, resolved offsets and
//! activation-group wiring against the meta tensor table and the
//! firmware graph for every built-in preset (including the odd
//! conv/pool sizes of the svhn stack), and check that graphs built
//! through the IR are bit-identical to the meta-driven path.

use std::path::PathBuf;

use hgq::coordinator::calibrate;
use hgq::data::try_splits_for;
use hgq::firmware::emulator::Emulator;
use hgq::firmware::{FwLayer, Graph};
use hgq::ir::{shape, IrOp, ModelIr};
use hgq::nn::ModelMeta;
use hgq::runtime::{ModelRuntime, Runtime};
use hgq::util::json::Json;

fn artifacts() -> PathBuf {
    // may or may not exist: the native backend falls back to presets
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

const PRESETS: [&str; 5] = ["jets_pp", "jets_lw", "muon_pp", "muon_lw", "svhn_stream"];

#[test]
fn ir_offsets_match_the_meta_tensor_table() {
    let rt = Runtime::new().unwrap();
    for model in PRESETS {
        let mr = ModelRuntime::load(&rt, &artifacts(), model).unwrap();
        let ir = &mr.ir;
        assert_eq!(ir.nodes.len(), mr.meta.layers.len(), "{model}: node count");
        assert_eq!(ir.state_size, mr.meta.state_size);
        assert_eq!(ir.n_params, mr.meta.n_params);
        assert_eq!(ir.n_train, mr.meta.n_train);
        assert_eq!(ir.calib_size, mr.meta.calib_size);
        assert_eq!(ir.input_dim, mr.meta.input_dim());
        assert_eq!(ir.output_dim, mr.meta.output_dim);

        // every group resolves to the tensor table + act-group entries
        assert_eq!(ir.groups.len(), mr.meta.act_groups.len(), "{model}: group count");
        for g in &ir.groups {
            let t = mr.meta.tensor(&g.name).unwrap();
            assert_eq!(g.f_offset, t.offset, "{model} {}: f offset", g.name);
            assert_eq!(g.f_size, t.size, "{model} {}: f size", g.name);
            let ag = mr.meta.act_group(&g.name).unwrap();
            assert_eq!(g.calib_offset, ag.calib_offset, "{model} {}: calib", g.name);
            assert_eq!(g.signed, ag.signed);
            let amin = mr.meta.tensor(&format!("{}.amin", g.name)).unwrap();
            let amax = mr.meta.tensor(&format!("{}.amax", g.name)).unwrap();
            assert_eq!(g.amin_offset, amin.offset);
            assert_eq!(g.amax_offset, amax.offset);
        }

        // every MAC param resolves to the tensor table
        for node in &ir.nodes {
            if let IrOp::Dense { w, b, .. } | IrOp::Conv2d { w, b, .. } = &node.op {
                let wt = mr.meta.tensor(&w.name).unwrap();
                assert_eq!((w.offset, w.size), (wt.offset, wt.size), "{model} {}", w.name);
                let bt = mr.meta.tensor(&b.name).unwrap();
                assert_eq!((b.offset, b.size), (bt.offset, bt.size), "{model} {}", b.name);
            }
        }
    }
}

#[test]
fn ir_shapes_and_group_wiring_chain_through_every_preset() {
    let rt = Runtime::new().unwrap();
    for model in PRESETS {
        let mr = ModelRuntime::load(&rt, &artifacts(), model).unwrap();
        let ir = &mr.ir;
        let mut cur: Option<usize> = None;
        let mut prev_out: Vec<usize> = ir.input_shape.clone();
        for node in &ir.nodes {
            // shapes chain: this node consumes exactly what the
            // previous one produced
            assert_eq!(node.in_shape, prev_out, "{model} {}: shape chain", node.name);
            match &node.op {
                IrOp::InputQuant { group } => {
                    assert_eq!(ir.groups[*group].feat_dim, ir.input_dim);
                    cur = Some(*group);
                }
                IrOp::Dense { din, dout, in_group, out_group, .. } => {
                    assert_eq!(Some(*in_group), cur, "{model} {}: in group", node.name);
                    assert_eq!(shape::flatten_dim(&node.in_shape), *din);
                    assert_eq!(node.out_shape, vec![*dout]);
                    assert_eq!(ir.groups[*out_group].feat_dim, *dout);
                    cur = Some(*out_group);
                }
                IrOp::Conv2d { k, cin, cout, oh, ow, in_h, in_w, in_group, out_group, .. } => {
                    assert_eq!(Some(*in_group), cur, "{model} {}: in group", node.name);
                    assert_eq!(node.in_shape, vec![*in_h, *in_w, *cin]);
                    assert_eq!(node.out_shape, vec![*oh, *ow, *cout]);
                    assert_eq!((*in_h, *in_w), (oh + k - 1, ow + k - 1));
                    assert_eq!(ir.groups[*out_group].feat_dim, oh * ow * cout);
                    cur = Some(*out_group);
                }
                IrOp::MaxPool2 { in_shape, out_shape } => {
                    assert_eq!(node.in_shape, in_shape.to_vec());
                    assert_eq!(shape::maxpool2_out_shape(in_shape).unwrap(), *out_shape);
                }
                IrOp::Flatten => {
                    assert_eq!(node.out_shape, vec![shape::flatten_dim(&node.in_shape)]);
                }
            }
            prev_out = node.out_shape.clone();
        }
        assert_eq!(shape::flatten_dim(&prev_out), ir.output_dim, "{model}: final dim");
    }
}

#[test]
fn svhn_ir_carries_the_true_odd_pool_shapes() {
    // the odd-pool regression of PR 2 in IR terms: the second pool
    // consumes 13x13 (not out_shape * 2 = 12x12)
    let rt = Runtime::new().unwrap();
    let mr = ModelRuntime::load(&rt, &artifacts(), "svhn_stream").unwrap();
    let pool_shapes: Vec<([usize; 3], [usize; 3])> = mr
        .ir
        .nodes
        .iter()
        .filter_map(|n| match &n.op {
            IrOp::MaxPool2 { in_shape, out_shape } => Some((*in_shape, *out_shape)),
            _ => None,
        })
        .collect();
    assert_eq!(pool_shapes.len(), 3);
    assert_eq!(pool_shapes[0], ([30, 30, 16], [15, 15, 16]));
    assert_eq!(pool_shapes[1], ([13, 13, 16], [6, 6, 16]));
    assert_eq!(pool_shapes[2], ([4, 4, 24], [2, 2, 24]));
}

#[test]
fn graph_from_ir_is_bit_identical_to_meta_build() {
    let rt = Runtime::new().unwrap();
    for model in ["jets_pp", "svhn_stream"] {
        let mr = ModelRuntime::load(&rt, &artifacts(), model).unwrap();
        let state = mr.init_state();
        let splits = try_splits_for(model, 11, 256, 1).unwrap();
        let calib = calibrate(&mr, &state, &[&splits.train]).unwrap();

        let g_meta = Graph::build(&mr.meta, &state, &calib).unwrap();
        let g_ir = Graph::from_ir(&mr.ir, &state, &calib).unwrap();
        assert_eq!(g_meta.layers.len(), g_ir.layers.len(), "{model}");
        assert_eq!(g_meta.exact_ebops(), g_ir.exact_ebops(), "{model}");
        assert_eq!(g_meta.max_width(), g_ir.max_width(), "{model}");
        assert_eq!(g_meta.sparsity(), g_ir.sparsity(), "{model}");
        for (a, b) in g_meta.layers.iter().zip(g_ir.layers.iter()) {
            if let (FwLayer::MaxPool2 { in_shape: ia }, FwLayer::MaxPool2 { in_shape: ib }) =
                (a, b)
            {
                assert_eq!(ia, ib, "{model}: pool input shapes");
            }
        }

        // emulated logits agree bit-for-bit
        let mut ea = Emulator::new(&g_meta);
        let mut eb = Emulator::new(&g_ir);
        let mut oa = vec![0.0f64; g_meta.output_dim];
        let mut ob = vec![0.0f64; g_ir.output_dim];
        for i in 0..8 {
            ea.infer(splits.train.sample(i), &mut oa).unwrap();
            eb.infer(splits.train.sample(i), &mut ob).unwrap();
            assert_eq!(oa, ob, "{model} sample {i}");
        }
    }
}

#[test]
fn ir_rejects_shape_inconsistent_meta() {
    // a meta whose dense layer disagrees with the inferred input dim
    // (input_shape [4] feeding din = 3) must fail IR resolution
    let j = Json::parse(
        r#"{
      "name":"bad","task":"cls","batch":4,"input_shape":[4],"y_dtype":"i32",
      "w_gran":"element","a_gran":"element",
      "state_size":40,"n_params":8,"n_train":22,"calib_size":6,"output_dim":2,
      "tensors":[
        {"name":"d0.w","shape":[3,2],"offset":0,"size":6,"seg":"param"},
        {"name":"d0.b","shape":[2],"offset":6,"size":2,"seg":"param"},
        {"name":"inq.fa","shape":[4],"offset":8,"size":4,"seg":"fbit"},
        {"name":"d0.fw","shape":[3,2],"offset":12,"size":6,"seg":"fbit"},
        {"name":"d0.fb","shape":[2],"offset":18,"size":2,"seg":"fbit"},
        {"name":"d0.fa","shape":[2],"offset":20,"size":2,"seg":"fbit"},
        {"name":"inq.fa.amin","shape":[4],"offset":22,"size":4,"seg":"stat"},
        {"name":"d0.fa.amin","shape":[2],"offset":26,"size":2,"seg":"stat"},
        {"name":"inq.fa.amax","shape":[4],"offset":28,"size":4,"seg":"stat"},
        {"name":"d0.fa.amax","shape":[2],"offset":32,"size":2,"seg":"stat"}],
      "act_groups":[
        {"name":"inq.fa","fshape":[4],"signed":true,"size":4},
        {"name":"d0.fa","fshape":[2],"signed":false,"size":2}],
      "layers":[
        {"kind":"input_quant","name":"inq","signed":true},
        {"kind":"dense","name":"d0","din":3,"dout":2,"act":"relu"}]
    }"#,
    )
    .unwrap();
    let meta = ModelMeta::from_json(&j).unwrap();
    let err = ModelIr::build(&meta).unwrap_err();
    assert!(format!("{err}").contains("input dim"), "{err}");
}
