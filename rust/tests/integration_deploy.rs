//! Deployment integration: calibration -> firmware -> exact EBOPs ->
//! resource simulation, including the golden software↔firmware checks
//! that back the paper's §IV bit-exactness guarantee. Runs hermetically
//! on the native backend (built-in presets, no artifacts).

use std::path::PathBuf;

use hgq::coordinator::{calibrate, deploy, train, BetaSchedule, TrainConfig};
use hgq::data::splits_for;
use hgq::firmware::emulator::Emulator;
use hgq::firmware::{FwLayer, Graph};
use hgq::runtime::{ModelRuntime, Runtime};

fn artifacts() -> PathBuf {
    // may or may not exist: the native backend falls back to presets
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn trained_jets(rt: &Runtime) -> (ModelRuntime, hgq::data::Splits, Vec<f32>) {
    let mr = ModelRuntime::load(rt, &artifacts(), "jets_pp").unwrap();
    let splits = splits_for("jets_pp", 5, 2048, 512);
    let cfg = TrainConfig {
        epochs: 5,
        lr: 3e-3,
        f_lr: 8.0,
        gamma: 2e-6,
        beta: BetaSchedule::Const(1e-6),
        seed: 5,
        val_every: 0,
        log_every: 0,
        reset_stats_each_epoch: true,
    };
    let out = train(&mr, &splits.train, &splits.val, &cfg, None).unwrap();
    (mr, splits, out.state)
}

#[test]
fn firmware_bit_exact_vs_forward_on_calibration_data_mlp() {
    // the §IV contract: inside the calibrated ranges, the integer
    // firmware and the backend's quantized forward agree EXACTLY for
    // the MLP (the native engine computes in f64, where every
    // fixed-point value and MLP-sized accumulation is exact)
    let rt = Runtime::new().unwrap();
    let (mr, splits, state) = trained_jets(&rt);
    let (_, rep) =
        deploy(&mr, "t", &state, &[&splits.train, &splits.val], &splits.test).unwrap();
    assert_eq!(rep.fw_vs_hlo_max_abs, 0.0, "MLP firmware must match the forward bit-exactly");
    assert!(rep.ebops > 0);
    assert!(rep.resources.lut > 0);
    assert_eq!(rep.resources.ii_cc, 1, "fully-unrolled MLP is II=1");
}

#[test]
fn firmware_bit_exact_vs_forward_on_calibration_data_conv() {
    // same §IV contract for the streaming CNN. Regression test for the
    // odd-pool stride bug: svhn's second pool consumes a 13x13 tensor
    // (dropping the last row/col); reconstructing its input shape as
    // out_shape * 2 = 12x12 mis-strided the emulator and silently broke
    // firmware↔forward agreement for every conv model
    let rt = Runtime::new().unwrap();
    let mr = ModelRuntime::load(&rt, &artifacts(), "svhn_stream").unwrap();
    let splits = splits_for("svhn_stream", 7, 256, 64);
    let state = mr.init_state();
    let (graph, rep) = deploy(&mr, "t", &state, &[&splits.train], &splits.test).unwrap();
    assert_eq!(rep.fw_vs_hlo_max_abs, 0.0, "conv firmware must match the forward bit-exactly");
    // the pool layers carry the TRUE (possibly odd) input shapes
    let pool_ins: Vec<[usize; 3]> = graph
        .layers
        .iter()
        .filter_map(|l| match l {
            FwLayer::MaxPool2 { in_shape } => Some(*in_shape),
            _ => None,
        })
        .collect();
    assert_eq!(pool_ins, vec![[30, 30, 16], [13, 13, 16], [4, 4, 24]]);
}

#[test]
fn exact_ebops_bounded_by_train_estimate_shape() {
    // EBOPs-bar (training) uses declared widths — the exact span-based
    // EBOPs of the deployed model must not exceed ~it by much, and both
    // must move together under pressure
    let rt = Runtime::new().unwrap();
    let (mr, splits, state) = trained_jets(&rt);
    let (graph, rep) =
        deploy(&mr, "t", &state, &[&splits.train, &splits.val], &splits.test).unwrap();
    let exact = graph.exact_ebops();
    assert_eq!(exact, rep.ebops);
    assert!(exact > 100, "EBOPs suspiciously small: {exact}");
}

#[test]
fn firmware_conv_matches_independent_f64_reference() {
    // independent cross-check of the conv/pool/dense indexing: an f64
    // reference implementation computed from the dequantized graph must
    // agree with the integer emulator wherever f64 is exact (it is: all
    // values are fixed-point with < 52 bits)
    let rt = Runtime::new().unwrap();
    let mr = ModelRuntime::load(&rt, &artifacts(), "svhn_stream").unwrap();
    let splits = splits_for("svhn_stream", 2, 128, 128);
    let state = mr.init_state();
    let calib = calibrate(&mr, &state, &[&splits.train]).unwrap();
    let graph = Graph::build(&mr.meta, &state, &calib).unwrap();

    let mut em = Emulator::new(&graph);
    let x = splits.train.sample(0);
    let mut got = vec![0.0f64; graph.output_dim];
    em.infer(x, &mut got).unwrap();
    let want = f64_reference(&graph, x);
    for j in 0..graph.output_dim {
        assert!(
            (got[j] - want[j]).abs() < 1e-9,
            "logit {j}: emulator {} vs f64 reference {}",
            got[j],
            want[j]
        );
    }
}

/// Naive f64 forward over the dequantized firmware graph (independent
/// of the emulator's integer code paths).
fn f64_reference(g: &Graph, x: &[f32]) -> Vec<f64> {
    let quant = |v: f64, s: hgq::fixed::FixedSpec| -> f64 {
        s.to_f64(s.quantize(v))
    };
    let mut cur: Vec<f64> = Vec::new();
    for l in &g.layers {
        match l {
            FwLayer::InputQuant { out } => {
                cur = x.iter().enumerate().map(|(i, &v)| quant(v as f64, out.spec(i))).collect();
            }
            FwLayer::Dense { din, dout, w, b, relu, out, .. } => {
                let mut next = vec![0.0f64; *dout];
                for (j, nj) in next.iter_mut().enumerate() {
                    let mut acc = b.value(j);
                    for i in 0..*din {
                        acc += cur[i] * w.value(i * dout + j);
                    }
                    if *relu {
                        acc = acc.max(0.0);
                    }
                    *nj = quant(acc, out.spec(j));
                }
                cur = next;
            }
            FwLayer::Conv2d { k, cin, cout, in_h, in_w, w, b, relu, out, .. } => {
                let (oh, ow) = (in_h - k + 1, in_w - k + 1);
                let mut next = vec![0.0f64; oh * ow * cout];
                for oy in 0..oh {
                    for ox in 0..ow {
                        for co in 0..*cout {
                            let mut acc = b.value(co);
                            for ky in 0..*k {
                                for kx in 0..*k {
                                    for ci in 0..*cin {
                                        let a = cur[((oy + ky) * in_w + ox + kx) * cin + ci];
                                        let wv = w.value(((ky * k + kx) * cin + ci) * cout + co);
                                        acc += a * wv;
                                    }
                                }
                            }
                            if *relu {
                                acc = acc.max(0.0);
                            }
                            let oi = (oy * ow + ox) * cout + co;
                            next[oi] = quant(acc, out.spec(oi));
                        }
                    }
                }
                cur = next;
            }
            FwLayer::MaxPool2 { in_shape } => {
                let [h, w, c] = *in_shape;
                let (oh, ow) = (h / 2, w / 2);
                let mut next = vec![0.0f64; oh * ow * c];
                for oy in 0..oh {
                    for ox in 0..ow {
                        for ch in 0..c {
                            let mut best = f64::NEG_INFINITY;
                            for dy in 0..2 {
                                for dx in 0..2 {
                                    best = best
                                        .max(cur[((oy * 2 + dy) * w + ox * 2 + dx) * c + ch]);
                                }
                            }
                            next[(oy * ow + ox) * c + ch] = best;
                        }
                    }
                }
                cur = next;
            }
            FwLayer::Flatten => {}
        }
    }
    cur
}

#[test]
fn pruning_baseline_reduces_resources() {
    let rt = Runtime::new().unwrap();
    let (mr, splits, mut state) = trained_jets(&rt);
    let (_, full) =
        deploy(&mr, "full", &state, &[&splits.train, &splits.val], &splits.test).unwrap();
    let pruned_n =
        hgq::baselines::prune_by_magnitude(&mr.meta, &mut state, 0.7).unwrap();
    assert!(pruned_n > 0);
    let (graph, rep) =
        deploy(&mr, "pruned", &state, &[&splits.train, &splits.val], &splits.test).unwrap();
    assert!(graph.sparsity() >= 0.5);
    assert!(rep.ebops < full.ebops, "pruning must cut EBOPs: {} vs {}", rep.ebops, full.ebops);
    assert!(rep.resources.lut < full.resources.lut);
}

#[test]
fn stream_conv_ii_counts_positions() {
    let rt = Runtime::new().unwrap();
    let mr = ModelRuntime::load(&rt, &artifacts(), "svhn_stream").unwrap();
    let splits = splits_for("svhn_stream", 2, 128, 128);
    let state = mr.init_state();
    let calib = calibrate(&mr, &state, &[&splits.train]).unwrap();
    let graph = Graph::build(&mr.meta, &state, &calib).unwrap();
    let r = hgq::resource::estimate(&graph);
    // first conv dominates: 30x30 = 900 positions (paper's streams run
    // at II ~= image positions)
    assert_eq!(r.ii_cc, 900);
    assert!(r.bram_18k > 0.0, "stream line buffers must use BRAM");
}
