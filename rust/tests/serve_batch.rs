//! Batch-invariance property of the serving engine: the layer-major
//! `BatchEmulator` (and everything stacked on it — the fixed shard
//! grid of `infer_all`, the micro-batching request pipeline) produces
//! logits **bit-identical** to sequential `Emulator::infer` calls, for
//! every preset graph, for odd batch sizes, and for `--threads` ∈
//! {1, 3, 16}. This is the guarantee that lets `hgq serve` and
//! `coordinator::deploy` batch freely without touching the paper's
//! software↔firmware correspondence.

use std::sync::Arc;

use hgq::data::splits_for;
use hgq::firmware::emulator::Emulator;
use hgq::firmware::Graph;
use hgq::serve::batch::{infer_all, BatchEmulator};
use hgq::serve::{serve_closed_loop, Registry, ServeConfig};

/// Zero-artifact deployed graph of a preset (init state, calibrated on
/// a small deterministic split — small keeps the dev-profile conv
/// forward affordable).
fn graph_for(model: &str, calib_n: usize) -> Arc<Graph> {
    Registry::new("artifacts").with_calib_samples(calib_n).get(model).unwrap()
}

/// Reference logits: one sample at a time through the scalar emulator.
fn sequential(g: &Graph, x: &[f32], n: usize) -> Vec<f64> {
    let (din, k) = (g.input_dim, g.output_dim);
    let mut em = Emulator::new(g);
    let mut out = vec![0.0f64; n * k];
    for s in 0..n {
        let (xi, oi) = (&x[s * din..(s + 1) * din], &mut out[s * k..(s + 1) * k]);
        em.infer(xi, oi).unwrap();
    }
    out
}

#[test]
fn batch_invariance_across_presets() {
    // (preset, calibration samples, K test samples) — K odd or prime so
    // micro-batches of 3 leave ragged tails
    for (model, calib_n, kk) in [
        ("jets_pp", 128, 9usize),
        ("jets_lw", 128, 7),
        ("muon_pp", 64, 7),
        ("svhn_stream", 32, 5),
    ] {
        let g = graph_for(model, calib_n);
        let (din, k) = (g.input_dim, g.output_dim);
        let splits = splits_for(model, 3, 1, kk);
        let x = &splits.test.x[..kk * din];
        let want = sequential(&g, x, kk);

        // batch of K vs K sequential infer calls, plus odd fills
        for bsz in [1usize, 3, kk] {
            let mut bem = BatchEmulator::new(&g, bsz);
            let mut got = vec![0.0f64; kk * k];
            let mut done = 0;
            while done < kk {
                let take = bsz.min(kk - done);
                let (xs, os) =
                    (&x[done * din..(done + take) * din], &mut got[done * k..(done + take) * k]);
                bem.infer_batch(xs, os).unwrap();
                done += take;
            }
            assert_eq!(got, want, "{model}: batch size {bsz} diverged from sequential");
        }

        // fixed shard grid: bit-identical for any worker-thread count
        for threads in [1usize, 3, 16] {
            let mut got = vec![0.0f64; kk * k];
            infer_all(&g, x, &mut got, threads, 4).unwrap();
            assert_eq!(got, want, "{model}: threads={threads} diverged from sequential");
        }
    }
}

#[test]
fn pipeline_matches_sequential_on_jets() {
    let g = graph_for("jets_pp", 128);
    let k = g.output_dim;
    let n_pool = 13;
    let splits = splits_for("jets_pp", 9, 1, n_pool);
    let pool = &splits.test.x;
    let want = sequential(&g, pool, n_pool);
    for workers in [1usize, 3, 16] {
        let cfg = ServeConfig {
            batch: 5, // odd fill vs 39 requests
            workers,
            queue_depth: 4,
            flush_us: 100,
            requests: 39,
            record_logits: true,
        };
        let outcome = serve_closed_loop(&g, pool, &cfg).unwrap();
        assert_eq!(outcome.report.requests, 39);
        let logits = outcome.logits.expect("recorded logits");
        for (id, lg) in logits.iter().enumerate() {
            let row = id % n_pool;
            assert_eq!(&lg[..], &want[row * k..(row + 1) * k], "workers={workers} id={id}");
        }
    }
}

#[test]
fn batch_emulator_capacity_guard_across_graphs() {
    let jets = graph_for("jets_pp", 64);
    let svhn = graph_for("svhn_stream", 32);
    let jets_lw = graph_for("jets_lw", 64);
    let mut bem = BatchEmulator::new(&jets, 4);
    // the CNN needs far wider scratch planes: refuse instead of panic
    let err = bem.retarget(&svhn).unwrap_err();
    assert!(format!("{err}").contains("warmed"), "{err}");
    // same-architecture graph (different granularity) retargets fine
    bem.retarget(&jets_lw).unwrap();
    let splits = splits_for("jets_lw", 5, 1, 3);
    let want = sequential(&jets_lw, &splits.test.x, 3);
    let mut got = vec![0.0f64; 3 * jets_lw.output_dim];
    bem.infer_batch(&splits.test.x, &mut got).unwrap();
    assert_eq!(got, want);
}
