//! Differential property harness for the HLS backend (co-equal with the
//! emitter itself, ARCHITECTURE.md §HLS backend): over randomly
//! generated `ModelIr` graphs, the emitted C++ firmware must be
//! **bit-identical** to the scalar `Emulator` golden model — proven by
//! actually compiling each emission with the host C++ compiler and
//! running its self-checking testbench. Along the way every case also
//! proves:
//!
//! * re-emission is byte-identical (pure-function determinism), and
//! * the static operator audit holds: CSD adder / DSP / tree-op counts
//!   in the generated source equal `resource::estimate`'s predictions.
//!
//! Case count defaults to 200 and is tunable via `HGQ_EMIT_PROP_CASES`
//! (CI's `emit-smoke` job runs a reduced count). Compile+run is
//! parallelized across temp dirs; emission and auditing stay on the
//! seeded deterministic path.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use hgq::firmware::{Calib, FwLayer, Graph};
use hgq::hls::{self, audit};
use hgq::ir::tier::KernelTier;
use hgq::util::prop::{check, gen_model_ir};
use hgq::util::rng::Rng;

fn prop_cases() -> u64 {
    std::env::var("HGQ_EMIT_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(200)
}

/// Six testbench vectors per model, derived from the graph's own input
/// specs: all-amax, all-amin, sign-alternating extremes, boundary
/// straddles (half a step OUTSIDE the range, so round-half-up lands
/// exactly on the wrap boundary), plus two random in-range fills.
fn tb_vectors(g: &Graph, rng: &mut Rng) -> Vec<f32> {
    let din = g.input_dim;
    let q = match &g.layers[0] {
        FwLayer::InputQuant { out } => out,
        other => panic!("first layer must be an input quantizer, got {other:?}"),
    };
    let n = 6usize;
    let mut x = vec![0.0f32; n * din];
    for s in 0..n {
        for i in 0..din {
            let sp = q.spec(i);
            let v = match s {
                0 => sp.max_value(),
                1 => sp.min_value(),
                2 => {
                    if i % 2 == 0 {
                        sp.max_value()
                    } else {
                        sp.min_value()
                    }
                }
                3 => {
                    if i % 2 == 0 {
                        sp.max_value() + 0.5 * sp.step()
                    } else {
                        sp.min_value() - 0.5 * sp.step()
                    }
                }
                _ => rng.range(sp.min_value(), sp.max_value()),
            };
            x[s * din + i] = v as f32;
        }
    }
    x
}

/// The tentpole property: for every generated graph, emission is
/// deterministic, the operator audit holds, and the compiled firmware
/// reproduces `Emulator::infer` bit-for-bit on adversarial vectors.
#[test]
fn prop_emitted_firmware_matches_emulator_bit_for_bit() {
    let cases = prop_cases();
    let mut emissions: Vec<hls::Emitted> = Vec::new();
    let mut narrow = 0usize;
    let mut csd_total = 0u64;
    let (mut seen_conv, mut seen_dense) = (false, false);
    check("emit-hls", cases, |rng| {
        let gm = gen_model_ir(rng);
        let calib = Calib { amin: gm.amin.clone(), amax: gm.amax.clone() };
        let g = Graph::from_ir(&gm.ir, &gm.state, &calib)
            .map_err(|e| format!("graph build failed: {e}"))?;
        let x = tb_vectors(&g, rng);
        let first = hls::emit(&g, &x).map_err(|e| format!("emit failed: {e:#}"))?;
        let again = hls::emit(&g, &x).map_err(|e| format!("re-emit failed: {e:#}"))?;
        if first != again {
            return Err("re-emission is not byte-identical".into());
        }
        let fw = first.file("firmware.cpp").expect("firmware.cpp emitted");
        let ops = audit::crosscheck(&g, fw).map_err(|e| format!("operator audit: {e:#}"))?;
        csd_total += ops.iter().map(|o| o.csd_ops).sum::<u64>();
        narrow += g
            .kernel_plan()
            .iter()
            .filter(|k| k.bound.is_some() && k.tier != KernelTier::Wide)
            .count();
        seen_conv |= g.layers.iter().any(|l| matches!(l, FwLayer::Conv2d { .. }));
        seen_dense |= g.layers.iter().any(|l| matches!(l, FwLayer::Dense { .. }));
        emissions.push(first);
        Ok(())
    });
    // non-vacuity: the generated population must actually exercise the
    // interesting emitter paths, or the property proved nothing
    assert!(narrow > 0, "no narrow accumulator tier ever engaged; narrow trees untested");
    assert!(csd_total > 0, "no CSD shift-add multiplier was ever emitted");
    assert!(seen_dense, "no dense layer was ever emitted");
    if cases >= 25 {
        assert!(seen_conv, "no conv stack was ever emitted");
    }

    // compile and run every emitted testbench with the host compiler —
    // parallel across temp dirs (`g++ -O0` dominates wall time; the
    // deterministic emission work above already ran single-threaded)
    let base = std::env::temp_dir().join(format!("hgq_emit_prop_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let next = AtomicUsize::new(0);
    let failures: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                let Some(e) = emissions.get(i) else { break };
                let dir = base.join(format!("case{i}"));
                let run = hls::write_to_dir(e, &dir).and_then(|_| hls::compile_and_run(&dir));
                if let Err(err) = run {
                    failures.lock().unwrap().push(format!("case {i}: {err:#}"));
                }
            });
        }
    });
    let failures = failures.into_inner().unwrap();
    assert!(
        failures.is_empty(),
        "emitted firmware diverged from the emulator on {} of {} cases:\n{}",
        failures.len(),
        emissions.len(),
        failures.join("\n")
    );
    let _ = std::fs::remove_dir_all(&base);
}
