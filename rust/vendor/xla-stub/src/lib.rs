//! Offline stub of the `xla` crate's PJRT surface.
//!
//! The hermetic build environment has no XLA/PJRT toolchain, but the
//! `pjrt` cargo feature must still *compile* so the feature-gated code
//! paths are type-checked in CI. This crate mirrors exactly the API
//! slice `hgq::runtime::pjrt` consumes; every entry point that would
//! touch a real PJRT client returns [`Error::Unavailable`] at runtime.
//!
//! To run the real thing, patch the workspace:
//!
//! ```toml
//! [patch."crates-io"]            # or edit rust/Cargo.toml's path dep
//! xla = { path = "/path/to/real/xla-rs" }
//! ```

use std::fmt;

/// Error type matching the call sites' `map_err(|e| anyhow!("{e:?}"))`
/// pattern (only `Debug` is required, `Display` provided for good
/// measure).
pub enum Error {
    /// The stub backend: no PJRT plugin is linked into this binary.
    Unavailable(&'static str),
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} requires a real PJRT build (this binary was compiled \
                 against rust/vendor/xla-stub; patch the `xla` path dependency)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

impl std::error::Error for Error {}

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host-side tensor stand-in. Never holds data in the stub: every
/// constructor is only reachable from code paths that already failed to
/// obtain a [`PjRtClient`].
pub struct Literal {
    _p: (),
}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _p: () }
    }

    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal { _p: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(Error::Unavailable("Literal::reshape"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        Err(Error::Unavailable("Literal::to_vec"))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T, Error> {
        Err(Error::Unavailable("Literal::get_first_element"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(Error::Unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module (text interchange).
pub struct HloModuleProto {
    _p: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// A computation ready for compilation.
pub struct XlaComputation {
    _p: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _p: () }
    }
}

/// Device-resident buffer handle.
pub struct PjRtBuffer {
    _p: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _p: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] is the only constructor and
/// always fails in the stub, which makes the rest of the API dead code
/// that nevertheless type-checks.
pub struct PjRtClient {
    _p: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_is_unavailable_with_actionable_error() {
        let err = PjRtClient::cpu().err().expect("stub must not hand out clients");
        let msg = format!("{err:?}");
        assert!(msg.contains("PjRtClient::cpu"));
        assert!(msg.contains("xla-stub"));
    }

    #[test]
    fn literal_constructors_exist_but_do_nothing() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2]).is_err());
        assert!(Literal::scalar(1i32).to_vec::<i32>().is_err());
    }
}
