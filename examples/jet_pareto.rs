//! Jet-tagging Pareto sweep (paper §V.B, Table I / Fig. III protocol):
//! ONE training run with a log-ramped β recovers the accuracy-vs-
//! resources Pareto front; six representatives are deployed as the
//! HGQ-1..6 table rows, next to the uniform (Q*-style) and layer-wise
//! (QKeras-style) baselines.
//!
//!     cargo run --release --example jet_pareto [epochs]

use anyhow::Result;

use hgq::coordinator::experiment::{
    preset, run_hgq_sweep, run_layerwise_baseline, run_uniform_baseline,
};
use hgq::runtime::Runtime;

fn main() -> Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::var("HGQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let epochs: Option<usize> = std::env::args().nth(1).and_then(|s| s.parse().ok());

    let rt = Runtime::new()?;
    let p = preset("jets");
    println!(
        "=== jet tagging Pareto sweep: {} epochs, beta {:.0e} -> {:.0e} ===",
        epochs.unwrap_or(p.epochs),
        p.beta_from,
        p.beta_to
    );

    let (_, _, outcome, reports) = run_hgq_sweep(&rt, &artifacts, &p, epochs, true)?;

    println!("\nPareto front ({} checkpoints) — quality vs EBOPs-bar:", outcome.pareto.len());
    for pt in outcome.pareto.sorted() {
        println!(
            "  epoch {:>4} beta {:.2e}: val-acc {:.4}  EBOPs-bar {:>9.0}",
            pt.epoch, pt.beta, pt.quality, pt.cost
        );
    }

    println!("\nHGQ rows (deployed, exact EBOPs + simulated place-and-route):");
    for r in &reports {
        println!("{}", r.row());
    }

    println!("\nbaselines:");
    for &bits in p.uniform_bits {
        let rep = run_uniform_baseline(&rt, &artifacts, &p, bits, epochs)?;
        println!("{}", rep.row());
    }
    for rep in run_layerwise_baseline(&rt, &artifacts, &p, epochs)? {
        println!("{}", rep.row());
    }

    // headline claim shape: the HGQ row matching baseline accuracy
    // should use a fraction of its LUTs
    println!("\n(compare rows at matched accuracy: HGQ should dominate — paper claims");
    println!(" 50-95% resource reduction at iso-accuracy on this task)");
    Ok(())
}
