//! Quickstart: the full HGQ workflow end-to-end on the jet tagger.
//!
//! This is the repository's E2E validation driver: it trains the
//! 16-64-32-32-5 MLP with per-parameter trainable bitwidths through the
//! hermetic pure-rust native backend (set `HGQ_BACKEND=pjrt` on a
//! `--features pjrt` build with real artifacts for the AOT/PJRT path),
//! logs the loss curve, then runs the complete deployment pipeline —
//! calibration (Eq. 3), bit-accurate firmware build, exact EBOPs,
//! simulated place-and-route — and checks the software↔firmware
//! bit-exactness contract.
//!
//!     cargo run --release --example quickstart
//!
//! Takes ~1 minute on a laptop-class CPU; no artifacts needed.

use anyhow::Result;

use hgq::coordinator::{deploy, train, BetaSchedule, TrainConfig};
use hgq::data::splits_for;
use hgq::runtime::{ModelRuntime, Runtime};

fn main() -> Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::var("HGQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    println!("=== HGQ quickstart: jet tagging, per-parameter bitwidths ===");

    let rt = Runtime::from_name(
        &std::env::var("HGQ_BACKEND").unwrap_or_else(|_| "native".into()),
    )?;
    println!("backend: {}", rt.platform());
    let mr = ModelRuntime::load(&rt, &artifacts, "jets_pp")?;
    println!(
        "model {}: packed state {} f32 ({} params, {} trainables), batch {}",
        mr.meta.name, mr.meta.state_size, mr.meta.n_params, mr.meta.n_train, mr.meta.batch
    );

    // synthetic jet data (see DESIGN.md substitutions)
    let splits = splits_for("jets_pp", 1, 8192, 2048);
    println!(
        "data: {} train / {} val / {} test samples, {} features",
        splits.train.n, splits.val.n, splits.test.n, splits.train.feat_dim
    );

    // train with a log-ramped resource pressure beta (the paper's
    // single-run Pareto protocol)
    let cfg = TrainConfig {
        epochs: 30,
        lr: 3e-3,
        f_lr: 8.0,
        gamma: 2e-6,
        beta: BetaSchedule::LogRamp { from: 1e-6, to: 3e-4 },
        seed: 0,
        val_every: 1,
        log_every: 3,
        reset_stats_each_epoch: true,
    };
    println!("\n--- training ({} epochs, beta 1e-6 -> 3e-4) ---", cfg.epochs);
    let out = train(&mr, &splits.train, &splits.val, &cfg, None)?;

    println!("\nloss curve (every 3rd epoch):");
    for log in out.logs.iter().step_by(3) {
        println!(
            "  epoch {:>3}: loss {:.4}  train-acc {:.3}  EBOPs-bar {:>8.0}  sparsity {:.2}  val-acc {}",
            log.epoch,
            log.loss,
            log.metric,
            log.ebops_bar,
            log.sparsity,
            log.val_quality.map(|v| format!("{v:.3}")).unwrap_or_else(|| "-".into()),
        );
    }
    println!("pareto front: {} checkpoints", out.pareto.len());

    // deploy two working points off the front: accuracy-optimal and
    // resource-optimal
    println!("\n--- deployment (calibrate -> firmware -> EBOPs -> resources) ---");
    let front = out.pareto.sorted();
    let picks: Vec<(&str, &hgq::coordinator::ParetoPoint)> =
        vec![("HGQ-hi", front.last().unwrap()), ("HGQ-lo", front.first().unwrap())];
    for (label, point) in picks {
        let (graph, rep) =
            deploy(&mr, label, &point.state, &[&splits.train, &splits.val], &splits.test)?;
        println!("{}", rep.row());
        assert_eq!(
            rep.fw_vs_hlo_max_abs, 0.0,
            "software/firmware correspondence must be bit-exact on calibration data"
        );
        println!(
            "  bit-exact sw<->fw: OK | graph layers: {} | exact EBOPs {} <= train bound {:.0}",
            graph.layers.len(),
            rep.ebops,
            point.cost
        );
    }
    println!("\nquickstart complete.");
    Ok(())
}
