//! Muon-tracker regression (paper §V.D, Table III / Fig. V): trains the
//! multistage MLP on simulated detector hits, deploys Pareto
//! representatives and the Qf* uniform baselines, and reports the
//! resolution (RMS with the paper's 30 mrad outlier cut) against
//! simulated resources.
//!
//!     cargo run --release --example muon_tracking [epochs]

use anyhow::Result;

use hgq::coordinator::deploy;
use hgq::coordinator::experiment::{preset, run_hgq_sweep, run_uniform_baseline};
use hgq::firmware::emulator::Emulator;
use hgq::metrics;
use hgq::runtime::Runtime;

fn main() -> Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::var("HGQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let epochs: Option<usize> = std::env::args().nth(1).and_then(|s| s.parse().ok());

    let rt = Runtime::new()?;
    let p = preset("muon");
    println!(
        "=== muon tracking: 3 stations x 3 layers x 50 strips -> angle (mrad) ===\n\
         {} epochs, beta {:.0e} -> {:.0e}",
        epochs.unwrap_or(p.epochs),
        p.beta_from,
        p.beta_to
    );

    let (mr, splits, outcome, reports) = run_hgq_sweep(&rt, &artifacts, &p, epochs, true)?;

    println!("\nHGQ rows (resolution in mrad, lower is better):");
    for r in &reports {
        println!("{}", r.row());
    }

    println!("\nQf* uniform baselines (the paper's comparison points):");
    for &bits in p.uniform_bits.iter().take(3) {
        let rep = run_uniform_baseline(&rt, &artifacts, &p, bits, epochs)?;
        println!("{}", rep.row());
    }

    // detailed look at the best working point: residual distribution
    if let Some(best) = outcome.pareto.sorted().last() {
        let (graph, rep) =
            deploy(&mr, "best", &best.state, &[&splits.train, &splits.val], &splits.test)?;
        let mut em = Emulator::new(&graph);
        let mut logits = vec![0.0f64; splits.test.n];
        em.infer_batch(&splits.test.x, &mut logits)?;
        let (rms, outliers) = metrics::resolution_with_cut(&logits, &splits.test.y_reg, 30.0);
        println!("\nbest point: resolution {rms:.2} mrad, outlier fraction {:.3}", outliers);
        println!("deployed: {}", rep.row());
        // residual histogram (10 mrad bins)
        let mut hist = [0usize; 12];
        for (pred, &t) in logits.iter().zip(&splits.test.y_reg) {
            let e = (pred - t as f64).abs();
            let bin = ((e / 5.0) as usize).min(11);
            hist[bin] += 1;
        }
        println!("|error| histogram (5 mrad bins):");
        for (i, &h) in hist.iter().enumerate() {
            println!(
                "  {:>3}-{:>3} mrad: {:<6} {}",
                i * 5,
                (i + 1) * 5,
                h,
                "#".repeat((h * 60 / splits.test.n).max(usize::from(h > 0)))
            );
        }
    }
    Ok(())
}
