//! SVHN-style digit classifier with stream-IO deployment (paper §V.C,
//! Table II / Fig. IV): per-parameter weight bitwidths + LAYER-wise
//! activation bitwidths (the stream-IO limitation the paper describes),
//! line-buffer BRAM accounting and position-count initiation interval.
//!
//! The CNN trains natively: the default pure-rust backend runs the full
//! sweep → calibrate → deploy → emulate pipeline with zero artifacts
//! (conv backward + batch-sharded executor; `HGQ_BACKEND=pjrt` on a
//! `--features pjrt` build with artifacts selects the AOT path). If the
//! selected backend cannot train, the backend-independent deployment
//! pipeline still runs from the initial state.
//!
//!     cargo run --release --example svhn_stream [epochs]

use anyhow::Result;

use hgq::coordinator::deploy;
use hgq::coordinator::experiment::{preset, run_hgq_sweep};
use hgq::data::splits_for;
use hgq::firmware::FwLayer;
use hgq::runtime::{ModelRuntime, Runtime};

fn main() -> Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::var("HGQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let epochs: Option<usize> = std::env::args().nth(1).and_then(|s| s.parse().ok());

    let rt = Runtime::from_name(
        &std::env::var("HGQ_BACKEND").unwrap_or_else(|_| "native".into()),
    )?;
    let p = preset("svhn");
    println!(
        "=== SVHN stream-IO CNN: conv16-conv16-conv24 + dense 42-64-10 ===\n\
         {} epochs, beta {:.0e} -> {:.0e} (w: per-parameter, a: layer-wise)",
        epochs.unwrap_or(p.epochs),
        p.beta_from,
        p.beta_to
    );

    // sweep when the backend can train the CNN; otherwise fall back to
    // deploying the untrained initial state
    let mr = ModelRuntime::load(&rt, &artifacts, p.model)?;
    let (best_state, label) = match run_hgq_sweep(&rt, &artifacts, &p, epochs, true) {
        Ok((_, _, outcome, reports)) => {
            println!("\nHGQ rows:");
            for r in &reports {
                println!("{}", r.row());
            }
            let best = outcome
                .pareto
                .sorted()
                .last()
                .map(|pt| pt.state.clone())
                .unwrap_or(outcome.state);
            (best, "best")
        }
        Err(err) => {
            println!("\n(sweep skipped: {err})");
            println!("(deploying the initial state to show the stream-IO structure)");
            (mr.init_state(), "init")
        }
    };

    let splits = splits_for(p.model, 1, p.n_train.min(2048), p.n_eval.min(512));
    let (graph, rep) =
        deploy(&mr, label, &best_state, &[&splits.train, &splits.val], &splits.test)?;
    println!("\ndeployed ({label}): {}", rep.row());
    println!("\nper-layer stream structure:");
    for l in &graph.layers {
        match l {
            FwLayer::Conv2d { k, cin, cout, in_h, in_w, w, out, .. } => {
                let nz = w.m.iter().filter(|&&m| m != 0).count();
                println!(
                    "  conv {k}x{k} {cin:>3} -> {cout:<3} @ {in_h}x{in_w}: act {} bits, {}/{} weights alive",
                    out.specs[0].bits,
                    nz,
                    w.m.len()
                );
            }
            FwLayer::Dense { din, dout, w, out, .. } => {
                let nz = w.m.iter().filter(|&&m| m != 0).count();
                println!(
                    "  dense {din:>4} -> {dout:<4}: act {} bits, {}/{} weights alive",
                    out.spec(0).bits,
                    nz,
                    w.m.len()
                );
            }
            _ => {}
        }
    }
    println!(
        "\nII = {} cc (stream positions), latency = {} cc ({:.2} µs) — paper's \
         stream implementations run at II ~1029, latency ~5.3 µs",
        rep.resources.ii_cc,
        rep.resources.latency_cc,
        rep.resources.latency_ns() / 1000.0
    );
    Ok(())
}
